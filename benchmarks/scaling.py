"""Figs 9/10 analogue: strong scaling with and without the communication
optimizations (hybrid pre/post + Int2), plus measured small-scale epochs.

Epoch time = Eqn-2/6 communication + streaming compute model, driven by
*measured* per-pair volumes from real partitions at P <= 32 and power-law
extrapolation beyond (the paper's 4 -> 8192-rank sweep is reproduced as a
model curve; the implementation itself is exercised end-to-end at P <= 8
by `convergence.py` and the test suite).

Run as a script, this additionally produces the repo's first *measured*
(wall-clock, real OS processes) scaling artifact: each config trains under
``exec.mode="multiproc"`` with overlap on and off, and the measured median
epoch time is recorded beside the ``hier_epoch_time`` *prediction* for the
same schedule — the modelled-vs-measured ledger ROADMAP's top item asks
for — plus the per-rank RSS evidence that P workers map ONE shared
partition copy and the per-epoch wire-byte counters proving cd>1 skips
the stale send.

  PYTHONPATH=src python benchmarks/scaling.py \\
      --out experiments/BENCH_scaling_measured.json [--quick]
"""

from __future__ import annotations

import time

import numpy as np

from repro.core.perf_model import FUGAKU_A64FX, epoch_time_model
from repro.graph import build_partitioned_graph, rmat_graph


def run(scale: int = 13, feat_dim: int = 256, hidden: int = 256,
        layers: int = 3) -> list:
    hw = FUGAKU_A64FX
    g = rmat_graph(scale, edge_factor=8, seed=3)
    rows = []
    meas = {}
    for nparts in (4, 8, 16, 32):
        pg = build_partitioned_graph(g, nparts, strategy="hybrid", seed=0)
        pg_post = build_partitioned_graph(g, nparts, part=pg.part, strategy="post")
        local_nnz = np.array([c.nnz for c in pg.local_csr], float)
        owned = np.array([len(o) for o in pg.owned], float)
        base = epoch_time_model(pg_post.stats.per_pair_hybrid.astype(float),
                                local_nnz, owned, feat_dim, hidden, layers,
                                hw, bits=0)
        opt = epoch_time_model(pg.stats.per_pair_hybrid.astype(float),
                               local_nnz, owned, feat_dim, hidden, layers,
                               hw, bits=2)
        meas[nparts] = (base["total"], opt["total"])
        rows.append({
            "name": f"scaling_fig10/P={nparts}/wo_comm_opt",
            "us_per_call": round(base["total"] * 1e6, 1),
            "derived": f"comm_share={base['comm'] / base['total']:.2f}",
        })
        rows.append({
            "name": f"scaling_fig10/P={nparts}/w_comm_opt",
            "us_per_call": round(opt["total"] * 1e6, 1),
            "derived": f"speedup={base['total'] / opt['total']:.2f}x",
        })
    # Strong-scaling extrapolation to paper scales.
    ps = np.array(sorted(meas))
    base_t = np.array([meas[p][0] for p in ps])
    kb, cb = np.polyfit(np.log(ps), np.log(base_t), 1)
    opt_t = np.array([meas[p][1] for p in ps])
    ko, co = np.polyfit(np.log(ps), np.log(opt_t), 1)
    for p in (256, 1024, 8192):
        tb = float(np.exp(cb) * p ** kb) + hw.latency * p  # latency floor
        to = float(np.exp(co) * p ** ko) + hw.latency * p
        rows.append({
            "name": f"scaling_fig10/P={p}/extrapolated",
            "us_per_call": round(to * 1e6, 1),
            "derived": f"speedup_w_vs_wo={tb / to:.2f}x",
        })

    # Measured wall-clock strong-scaling artifact of the real implementation
    # (vmap virtual workers on 1 CPU core: constant-work check, not speedup),
    # driven through the declarative RunSpec like every other run.
    from repro.run import BuildCache, RunSpec, build_session
    cache = BuildCache()
    base = RunSpec().with_overrides([
        "graph.source=rmat", "graph.scale=10", "graph.edge_factor=6",
        "graph.seed=4", "graph.feat_dim=32", "graph.features=random",
        "graph.feat_noise=1.0", "graph.classes=8",
        "schedule.bits=2", "model.hidden_dim=64", "model.dropout=0.0",
        "model.label_prop=false"])
    for nparts in (2, 4, 8):
        spec = base.with_overrides([f"partition.nparts={nparts}"])
        session = build_session(spec, cache=cache)
        session.train_epoch()  # compile
        t0 = time.perf_counter()
        for _ in range(3):
            session.train_epoch()
        dt = (time.perf_counter() - t0) / 3
        rows.append({
            "name": f"scaling_measured/P={nparts}/int2_epoch",
            "us_per_call": round(dt * 1e6, 1),
            "derived": (f"halo_rows={session.comm_stats().hybrid},"
                        f"spec={spec.content_hash()}"),
        })
    return rows


# --------------------------------------------------------------------------
# Measured multi-process scaling (the checked-in artifact)
# --------------------------------------------------------------------------


def _measured_configs(scale: int):
    """(label, RunSpec) configs of the measured sweep: the flagship
    hierarchical Int2/cd=2 spec at P=4 real processes (the acceptance
    config), plus an rmat row at ``--scale`` for CI smoke."""
    from repro.run import RunSpec

    flagship = RunSpec.load("specs/flagship_hier_int2_overlap.json")
    flagship = flagship.with_overrides([
        "exec.mode=multiproc", "partition.nparts=4", "exec.nprocs=4"])
    rmat = RunSpec().with_overrides([
        "graph.source=rmat", f"graph.scale={scale}", "graph.edge_factor=6",
        "graph.seed=4", "graph.feat_dim=16", "graph.features=random",
        "graph.feat_noise=1.0", "graph.classes=8", "graph.norm=mean",
        "partition.nparts=4", "partition.groups=2",
        "schedule.inter_bits=2", "schedule.inter_cd=2",
        "schedule.agg_backend=ell", "model.hidden_dim=32",
        "model.dropout=0.0", "model.label_prop=false",
        "exec.mode=multiproc"])
    return [("flagship_p4", flagship), (f"rmat{scale}_p4", rmat)]


def _predicted(session) -> dict:
    """``hier_epoch_time`` for the session's schedule — the model column
    the measured column sits beside. Modelled for the paper's A64FX
    fabric, so the *ratios* (sequential/overlap, hidden fraction) are the
    comparable quantities, not the absolute seconds."""
    from repro.core.perf_model import hier_epoch_time

    spec = session.spec
    f = spec.graph.feat_dim
    stage_bytes = session.predicted_wire_bytes()
    pg = session.pg
    m = hier_epoch_time(
        stage_bytes.get("intra", 0.0),
        stage_bytes.get("inter", stage_bytes.get("flat", 0.0)),
        local_nnz=[c.nnz for c in pg.local_csr],
        owned_rows=[len(o) for o in pg.owned],
        feat_dim=f, hidden_dim=spec.model.hidden_dim,
        num_layers=spec.model.num_layers, hw=FUGAKU_A64FX)
    return {k: (round(v, 8) if isinstance(v, float) else v)
            for k, v in m.items()}


def _run_measured(spec, epochs: int, warmup: int) -> dict:
    """Train ``spec`` under multiproc and return measured stats."""
    from repro.run import build_session

    session = build_session(spec)
    rt = session.trainer
    try:
        for _ in range(warmup):
            rt.train_epoch()
        base = len(rt.epoch_stats)
        for _ in range(epochs):
            rt.train_epoch()
        stats = rt.epoch_stats[base:]
        smry = rt.summary()
        predicted = _predicted(session)
        token = rt.token
    finally:
        session.close()
    from repro.launch.shm_store import leaked_segments
    times = sorted(s["epoch_s"] for s in stats)
    wire = [s["wire_bytes"][0] for s in stats]
    return {
        "spec_hash": spec.content_hash(),
        "nprocs": rt.nprocs,
        "epochs_timed": epochs,
        "median_epoch_s": round(times[len(times) // 2], 4),
        "min_epoch_s": round(times[0], 4),
        "mean_wait_s": round(
            float(np.mean([np.mean(s["wait_s"]) for s in stats])), 4),
        "wire_bytes_per_epoch": sorted(set(wire)),
        "predicted_a64fx": predicted,
        "rss": {
            "store_mb": round(smry["store_bytes"] / 1e6, 2),
            "rank_after_attach_mb": [
                round(r["rss_after_attach"] / 1e6, 1)
                for r in smry["ranks"]],
            "rank_after_slices_mb": [
                round(r["rss_after_slices"] / 1e6, 1)
                for r in smry["ranks"]],
        },
        "leaked_segments": leaked_segments(token),
    }


def measured_scaling(scale: int = 10, epochs: int = 8,
                     warmup: int = 2) -> dict:
    """The measured-vs-modelled artifact body (see module docstring)."""
    import os

    rows = []
    for label, spec in _measured_configs(scale):
        for overlap in (True, False):
            run_spec = spec.with_overrides(
                [f"schedule.overlap={'true' if overlap else 'false'}"])
            row = _run_measured(run_spec, epochs, warmup)
            row["name"] = (f"scaling_measured_multiproc/{label}/"
                           f"{'overlap' if overlap else 'no_overlap'}")
            rows.append(row)
            print(f"# {row['name']}: median {row['median_epoch_s']}s, "
                  f"wait {row['mean_wait_s']}s", flush=True)
        on, off = rows[-2], rows[-1]
        rows[-2]["overlap_speedup_measured"] = round(
            off["median_epoch_s"] / on["median_epoch_s"], 4)
        pred = on["predicted_a64fx"]
        rows[-2]["overlap_speedup_predicted"] = round(
            pred["sequential"] / pred["overlap"], 4) if pred["overlap"] else 1.0
    return {
        "benchmark": "scaling_measured_multiproc",
        "host_cpus": os.cpu_count(),
        "scale": scale,
        "epochs_timed": epochs,
        "rows": rows,
    }


def main() -> None:
    import argparse
    import json

    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--out", default="experiments/BENCH_scaling_measured.json")
    ap.add_argument("--scale", type=int, default=10,
                    help="rmat scale of the smoke config (default 10)")
    ap.add_argument("--epochs", type=int, default=8)
    ap.add_argument("--warmup", type=int, default=2)
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke: fewer timed epochs")
    args = ap.parse_args()
    epochs = 4 if args.quick else args.epochs
    artifact = measured_scaling(scale=args.scale, epochs=epochs,
                                warmup=args.warmup)
    artifact["quick"] = args.quick
    with open(args.out, "w") as f:
        json.dump(artifact, f, indent=1)
        f.write("\n")
    print(f"# wrote {args.out}")
    leaks = [r["leaked_segments"] for r in artifact["rows"]
             if r["leaked_segments"]]
    slower = [r["name"] for r in artifact["rows"]
              if r.get("overlap_speedup_measured", 1.0) < 1.0]
    if leaks:
        raise SystemExit(f"shared-memory segments leaked: {leaks}")
    if slower:
        print(f"# WARNING: overlap-on not faster for {slower}")


if __name__ == "__main__":
    main()
