"""Figs 9/10 analogue: strong scaling with and without the communication
optimizations (hybrid pre/post + Int2), plus measured small-scale epochs.

Epoch time = Eqn-2/6 communication + streaming compute model, driven by
*measured* per-pair volumes from real partitions at P <= 32 and power-law
extrapolation beyond (the paper's 4 -> 8192-rank sweep is reproduced as a
model curve; the implementation itself is exercised end-to-end at P <= 8
by `convergence.py` and the test suite).
"""

from __future__ import annotations

import time

import numpy as np

from repro.core.perf_model import FUGAKU_A64FX, epoch_time_model
from repro.graph import build_partitioned_graph, rmat_graph


def run(scale: int = 13, feat_dim: int = 256, hidden: int = 256,
        layers: int = 3) -> list:
    hw = FUGAKU_A64FX
    g = rmat_graph(scale, edge_factor=8, seed=3)
    rows = []
    meas = {}
    for nparts in (4, 8, 16, 32):
        pg = build_partitioned_graph(g, nparts, strategy="hybrid", seed=0)
        pg_post = build_partitioned_graph(g, nparts, part=pg.part, strategy="post")
        local_nnz = np.array([c.nnz for c in pg.local_csr], float)
        owned = np.array([len(o) for o in pg.owned], float)
        base = epoch_time_model(pg_post.stats.per_pair_hybrid.astype(float),
                                local_nnz, owned, feat_dim, hidden, layers,
                                hw, bits=0)
        opt = epoch_time_model(pg.stats.per_pair_hybrid.astype(float),
                               local_nnz, owned, feat_dim, hidden, layers,
                               hw, bits=2)
        meas[nparts] = (base["total"], opt["total"])
        rows.append({
            "name": f"scaling_fig10/P={nparts}/wo_comm_opt",
            "us_per_call": round(base["total"] * 1e6, 1),
            "derived": f"comm_share={base['comm'] / base['total']:.2f}",
        })
        rows.append({
            "name": f"scaling_fig10/P={nparts}/w_comm_opt",
            "us_per_call": round(opt["total"] * 1e6, 1),
            "derived": f"speedup={base['total'] / opt['total']:.2f}x",
        })
    # Strong-scaling extrapolation to paper scales.
    ps = np.array(sorted(meas))
    base_t = np.array([meas[p][0] for p in ps])
    kb, cb = np.polyfit(np.log(ps), np.log(base_t), 1)
    opt_t = np.array([meas[p][1] for p in ps])
    ko, co = np.polyfit(np.log(ps), np.log(opt_t), 1)
    for p in (256, 1024, 8192):
        tb = float(np.exp(cb) * p ** kb) + hw.latency * p  # latency floor
        to = float(np.exp(co) * p ** ko) + hw.latency * p
        rows.append({
            "name": f"scaling_fig10/P={p}/extrapolated",
            "us_per_call": round(to * 1e6, 1),
            "derived": f"speedup_w_vs_wo={tb / to:.2f}x",
        })

    # Measured wall-clock strong-scaling artifact of the real implementation
    # (vmap virtual workers on 1 CPU core: constant-work check, not speedup),
    # driven through the declarative RunSpec like every other run.
    from repro.run import BuildCache, RunSpec, build_session
    cache = BuildCache()
    base = RunSpec().with_overrides([
        "graph.source=rmat", "graph.scale=10", "graph.edge_factor=6",
        "graph.seed=4", "graph.feat_dim=32", "graph.features=random",
        "graph.feat_noise=1.0", "graph.classes=8",
        "schedule.bits=2", "model.hidden_dim=64", "model.dropout=0.0",
        "model.label_prop=false"])
    for nparts in (2, 4, 8):
        spec = base.with_overrides([f"partition.nparts={nparts}"])
        session = build_session(spec, cache=cache)
        session.train_epoch()  # compile
        t0 = time.perf_counter()
        for _ in range(3):
            session.train_epoch()
        dt = (time.perf_counter() - t0) / 3
        rows.append({
            "name": f"scaling_measured/P={nparts}/int2_epoch",
            "us_per_call": round(dt * 1e6, 1),
            "derived": (f"halo_rows={session.comm_stats().hybrid},"
                        f"spec={spec.content_hash()}"),
        })
    return rows
