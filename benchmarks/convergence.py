"""Fig 11 / Table 3 analogue: accuracy of FP32 vs Int2, with and without
masked label propagation, on an SBM node-classification task (the synthetic
stand-in with a learnable signal — DESIGN.md §8.3).

Paper pattern: Int2 ~ FP32 on easier datasets; on hard ones Int2 w/o LP
drops and LP recovers it. Also runs the DistGNN-style cd-5 delayed-comm
baseline the paper compares against on ABCI.

``convergence_hier_baseline/`` re-baselines the *hierarchical default*
schedule (Int2 inter wire — ``HIER_INTER_BITS_DEFAULT``) on a larger SBM
task against the explicitly-pinned fp32 slow wire: the acceptance evidence
that the flipped default costs no accuracy while shipping ~13x smaller
inter bytes. ``python benchmarks/convergence.py --out FILE`` writes the
rows (spec dicts + content hashes included) as a JSON artifact; the
checked-in baseline lives at ``experiments/BENCH_convergence.json``.

Every run is a :class:`repro.run.RunSpec` driven through
``build_session``; rows carry the spec content hash that names their
exact configuration.
"""

from __future__ import annotations

import argparse
import json
import time

from repro.run import BuildCache, RunSpec, build_session


def run(epochs: int = 30, nparts: int = 4) -> list:
    base = RunSpec().with_overrides([
        "graph.source=sbm", "graph.nodes=1500", "graph.classes=8",
        "graph.avg_degree=10", "graph.homophily=0.75", "graph.seed=10",
        "graph.feat_dim=32", "graph.feat_noise=3.0",
        f"partition.nparts={nparts}",
        "model.hidden_dim=64", "model.dropout=0.2",
        f"exec.epochs={epochs}", "exec.lr=0.01", "exec.seed=0",
    ])
    cache = BuildCache()
    rows = []
    settings = [
        ("fp32_wo_lp", 0, False, 1),
        ("fp32_w_lp", 0, True, 1),
        ("int2_wo_lp", 2, False, 1),
        ("int2_w_lp", 2, True, 1),
        ("distgnn_cd5_baseline", 0, False, 5),
    ]
    for name, bits, lp, cd in settings:
        spec = base.with_overrides([
            f"schedule.bits={bits}", f"schedule.cd={cd}",
            f"model.label_prop={'true' if lp else 'false'}"])
        session = build_session(spec, cache=cache)
        t0 = time.perf_counter()
        session.fit(log_every=0)
        dt = (time.perf_counter() - t0) / epochs
        acc = session.evaluate()
        rows.append({
            "name": f"convergence_fig11/{name}",
            "us_per_call": round(dt * 1e6, 1),
            "derived": f"eval_acc={acc:.4f},spec={spec.content_hash()}",
        })
    rows.extend(run_hier_baseline(epochs=max(epochs, 30)))
    return rows


def run_hier_baseline(epochs: int = 30, nodes: int = 3000,
                      num_groups: int = 2, group_size: int = 2,
                      with_specs: bool = False) -> list:
    """Re-baseline the hierarchical default (Int2 inter wire) on a larger
    SBM task than the bits_ablation_stage evidence used.

    Three schedules, same task/partition: the shipped default (fp32 intra,
    Int2 inter — no overrides), the pinned fp32 slow wire
    (``inter_bits=0``), and Int2 everywhere. The default must match the
    fp32 baseline's accuracy while its inter wire carries Int2-sized
    bytes — the convergence re-baseline ROADMAP asked for before flipping.
    """
    nparts = num_groups * group_size
    base = RunSpec().with_overrides([
        "graph.source=sbm", f"graph.nodes={nodes}", "graph.classes=10",
        "graph.avg_degree=12", "graph.homophily=0.78", "graph.seed=31",
        "graph.feat_dim=48", "graph.feat_noise=2.8",
        f"partition.nparts={nparts}", f"partition.groups={num_groups}",
        "model.hidden_dim=96", "model.dropout=0.2",
        f"exec.epochs={epochs}", "exec.lr=0.01", "exec.seed=0",
    ])
    cache = BuildCache()
    rows = []
    for name, overrides in (
            ("default_int2_inter", []),          # the flipped default
            ("pinned_fp32_inter", ["schedule.inter_bits=0"]),
            ("int2_everywhere", ["schedule.bits=2"])):
        spec = base.with_overrides(overrides)
        session = build_session(spec, cache=cache)
        t0 = time.perf_counter()
        session.fit(log_every=0)
        dt = (time.perf_counter() - t0) / epochs
        acc = session.evaluate()
        sb = session.predicted_wire_bytes()
        row = {
            "name": f"convergence_hier_baseline/{name}",
            "us_per_call": 0.0,
            "derived": (f"eval_acc={acc:.4f},"
                        f"intra_wire_b={sb['intra']:.0f},"
                        f"inter_wire_b={sb['inter']:.0f},"
                        f"epoch_s={dt:.3f},spec={spec.content_hash()}"),
        }
        if with_specs:
            row["spec_hash"] = spec.content_hash()
            row["spec"] = spec.to_dict()
            row["eval_acc"] = acc
            row["wire_bytes"] = sb
        rows.append(row)
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--epochs", type=int, default=40)
    ap.add_argument("--out", type=str, default=None,
                    help="write the hierarchical re-baseline rows (incl. "
                         "spec dicts + hashes) as a JSON artifact")
    args = ap.parse_args()
    rows = run_hier_baseline(epochs=args.epochs, with_specs=True)
    for r in rows:
        print(f"{r['name']},{r['us_per_call']},{r['derived']}")
    if args.out:
        with open(args.out, "w") as f:
            json.dump(rows, f, indent=1)
        print(f"wrote {len(rows)} re-baseline rows to {args.out}")


if __name__ == "__main__":
    main()
