"""Fig 11 / Table 3 analogue: accuracy of FP32 vs Int2, with and without
masked label propagation, on an SBM node-classification task (the synthetic
stand-in with a learnable signal — DESIGN.md §8.3).

Paper pattern: Int2 ~ FP32 on easier datasets; on hard ones Int2 w/o LP
drops and LP recovers it. Also runs the DistGNN-style cd-5 delayed-comm
baseline the paper compares against on ABCI.
"""

from __future__ import annotations

import time

import numpy as np

from repro.core import DistConfig, DistributedTrainer, GCNConfig, prepare_distributed
from repro.graph import build_partitioned_graph, sbm_graph
from repro.graph.generators import sbm_features


def run(epochs: int = 30, nparts: int = 4) -> list:
    g = sbm_graph(1500, 8, avg_degree=10, homophily=0.75, seed=10)
    x, _ = sbm_features(g, 32, noise=3.0, seed=11)
    gn = g.mean_normalized()
    pg = build_partitioned_graph(gn, nparts, strategy="hybrid", seed=0)
    wd = prepare_distributed(gn, x, pg)
    rows = []
    settings = [
        ("fp32_wo_lp", 0, False, 1),
        ("fp32_w_lp", 0, True, 1),
        ("int2_wo_lp", 2, False, 1),
        ("int2_w_lp", 2, True, 1),
        ("distgnn_cd5_baseline", 0, False, 5),
    ]
    for name, bits, lp, cd in settings:
        cfg = GCNConfig(model="sage", in_dim=32, hidden_dim=64, num_classes=8,
                        num_layers=3, dropout=0.2, label_prop=lp, norm="layer")
        tr = DistributedTrainer(cfg, DistConfig(nparts=nparts, bits=bits,
                                                cd=cd, lr=0.01),
                                wd, mode="vmap", seed=0)
        t0 = time.perf_counter()
        tr.fit(epochs)
        dt = (time.perf_counter() - t0) / epochs
        acc = tr.evaluate()
        rows.append({
            "name": f"convergence_fig11/{name}",
            "us_per_call": round(dt * 1e6, 1),
            "derived": f"eval_acc={acc:.4f}",
        })
    return rows
