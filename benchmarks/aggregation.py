"""Fig 8 analogue: aggregation-operator performance on a single CPU.

Compares three realizations of the paper's `index_add`/SpMM stage on
synthetic graphs of increasing size:

  vanilla   — scatter-add in edge order (PyG-baseline access pattern:
              random writes to dst rows),
  sorted    — scatter-add after sorting edges by destination (the paper's
              "clustering and sorting" step alone),
  ell       — the blocked-ELL layout consumed by the Pallas kernel
              (dst-clustered gather + dense accumulate; the kernel itself
              targets TPU and is validated in interpret mode, so the CPU
              timing here exercises the same memory-access structure
              through XLA).

The paper reports 1.8-8.4x over PyG on Xeon; the reproduction target is the
*ordering* (clustered >= sorted > vanilla) and growing advantage with size.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.graph import rmat_graph
from repro.graph.structure import ell_from_csr
from repro.kernels.ref import seg_aggregate_ref


def _time(fn, *args, iters=5):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6  # us


def run(feat_dim: int = 128, scales=(10, 12, 14)) -> list:
    rows = []
    for scale in scales:
        g = rmat_graph(scale, edge_factor=8, seed=scale).mean_normalized()
        n = g.num_nodes
        x = jnp.asarray(np.random.default_rng(0).normal(
            size=(n, feat_dim)).astype(np.float32))

        # vanilla: edge-order scatter add (random dst writes)
        src = jnp.asarray(g.src, jnp.int32)
        dst = jnp.asarray(g.dst, jnp.int32)
        w = jnp.asarray(g.edge_weight)

        @jax.jit
        def vanilla(x, src=src, dst=dst, w=w, n=n):
            return jnp.zeros((n, x.shape[1]), x.dtype).at[dst].add(
                w[:, None] * x[src])

        # sorted: same scatter after dst-sort (paper §4 step 1)
        order = np.argsort(np.asarray(g.dst), kind="stable")
        src_s = jnp.asarray(g.src[order], jnp.int32)
        dst_s = jnp.asarray(g.dst[order], jnp.int32)
        w_s = jnp.asarray(g.edge_weight[order])

        @jax.jit
        def sorted_scatter(x, src=src_s, dst=dst_s, w=w_s, n=n):
            return jnp.zeros((n, x.shape[1]), x.dtype).at[dst].add(
                w[:, None] * x[src])

        # clustered: dst-sorted segment accumulate (indices_are_sorted lets
        # XLA use the contiguous-run path — the CPU-visible form of the
        # paper's clustering insight; the blocked-ELL layout itself targets
        # the TPU kernel and is validated in interpret mode, not timed here)
        @jax.jit
        def clustered(x, src=src_s, dst=dst_s, w=w_s, n=n):
            return jax.ops.segment_sum(w[:, None] * x[src], dst,
                                       num_segments=n, indices_are_sorted=True)

        t_van = _time(vanilla, x)
        t_sort = _time(sorted_scatter, x)
        t_clu = _time(clustered, x)
        rows.append({
            "name": f"aggregation_fig8/rmat{scale}/vanilla",
            "us_per_call": round(t_van, 1),
            "derived": f"edges={g.num_edges}",
        })
        rows.append({
            "name": f"aggregation_fig8/rmat{scale}/sorted",
            "us_per_call": round(t_sort, 1),
            "derived": f"speedup_vs_vanilla={t_van / t_sort:.2f}x",
        })
        rows.append({
            "name": f"aggregation_fig8/rmat{scale}/clustered_segment",
            "us_per_call": round(t_clu, 1),
            "derived": f"speedup_vs_vanilla={t_van / t_clu:.2f}x",
        })
    return rows
