"""Fig 8 analogue: aggregation-operator performance on a single CPU.

Compares realizations of the paper's `index_add`/SpMM stage on synthetic
R-MAT graphs of increasing size:

  vanilla   — scatter-add in edge order (PyG-baseline access pattern:
              random writes to dst rows),
  sorted    — scatter-add after sorting edges by destination (the paper's
              "clustering and sorting" step alone),
  clustered — dst-sorted segment accumulate (indices_are_sorted lets XLA
              use the contiguous-run path),
  ell       — max-degree padded ELL (dst-clustered gather + dense
              accumulate). On power-law graphs the padding blows up as
              rows x max_degree, so large scales report the slot count and
              skip the timing — the reason this layout never reached the
              training loop,
  bucketed  — the production layout: degree-bucketed blocked-ELL
              (growth-2 ladder, total padded slots < 2 x nnz) dispatched
              through the same segment-aggregate primitive the distributed
              trainer uses (XLA realization on CPU),
  kernel    — the same bucketed layout through the Pallas kernel in
              interpret mode (functional check only; the compiled kernel
              targets TPU), smallest scale only.

The paper reports 1.8-8.4x over PyG on Xeon; the reproduction target is
the *ordering* (bucketed/clustered >= sorted > vanilla), bounded bucketed
padding (<= 2 x nnz, asserted), and a growing advantage with size.

CLI:
  python benchmarks/aggregation.py [--quick] [--feat-dim F] [--out FILE]

``--out`` writes a machine-readable JSON artifact (rows + per-scale layout
accounting + acceptance booleans); CI archives it next to the comm-volume
sweep, and the checked-in copy lives at experiments/BENCH_aggregation.json.
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.graph import rmat_graph
from repro.graph.structure import (
    bucketed_ell_from_csr,
    ell_from_csr,
    stack_bucketed_ells,
    transpose_csr,
)
from repro.kernels import bucketed_aggregate, device_bucketed
from repro.kernels.ref import seg_aggregate_ref

# Timing the full max-degree ELL needs a [rows, max_degree, F] gather in
# memory; past this many padded slots we report the blow-up instead.
ELL_TIMING_SLOT_BUDGET = 1 << 21


def _time(fn, *args, iters=5):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6  # us


def _bench_scale(scale: int, feat_dim: int, iters: int,
                 with_kernel: bool) -> tuple:
    """Rows + layout accounting for one R-MAT scale."""
    g = rmat_graph(scale, edge_factor=8, seed=scale).mean_normalized()
    n = g.num_nodes
    x = jnp.asarray(np.random.default_rng(0).normal(
        size=(n, feat_dim)).astype(np.float32))
    csr = g.csr_by_dst()
    deg = csr.row_degrees()
    max_deg = int(deg.max())

    # vanilla: edge-order scatter add (random dst writes)
    src = jnp.asarray(g.src, jnp.int32)
    dst = jnp.asarray(g.dst, jnp.int32)
    w = jnp.asarray(g.edge_weight)

    @jax.jit
    def vanilla(x, src=src, dst=dst, w=w, n=n):
        return jnp.zeros((n, x.shape[1]), x.dtype).at[dst].add(
            w[:, None] * x[src])

    # sorted: same scatter after dst-sort (paper §4 step 1)
    order = np.argsort(np.asarray(g.dst), kind="stable")
    src_s = jnp.asarray(g.src[order], jnp.int32)
    dst_s = jnp.asarray(g.dst[order], jnp.int32)
    w_s = jnp.asarray(g.edge_weight[order])

    @jax.jit
    def sorted_scatter(x, src=src_s, dst=dst_s, w=w_s, n=n):
        return jnp.zeros((n, x.shape[1]), x.dtype).at[dst].add(
            w[:, None] * x[src])

    # clustered: dst-sorted segment accumulate
    @jax.jit
    def clustered(x, src=src_s, dst=dst_s, w=w_s, n=n):
        return jax.ops.segment_sum(w[:, None] * x[src], dst,
                                   num_segments=n, indices_are_sorted=True)

    # bucketed: the trainer's hot path (degree-bucketed blocked-ELL through
    # the segment-aggregate primitive; ref/XLA realization on CPU)
    ell = bucketed_ell_from_csr(csr)
    ell_t = bucketed_ell_from_csr(transpose_csr(csr))
    dell = device_bucketed(stack_bucketed_ells([ell]), squeeze=True)
    dell_t = device_bucketed(stack_bucketed_ells([ell_t]), squeeze=True)
    # Device slots include the 8-row kernel alignment sliver; the < 2 x nnz
    # ladder guarantee (asserted below) is on the pre-alignment layout.
    layout_slots = ell.padded_slots
    bucketed_slots = sum(int(b.idx.shape[0]) * int(b.idx.shape[1])
                         for b in dell.buckets)

    @jax.jit
    def bucketed(x, dell=dell, dell_t=dell_t):
        return bucketed_aggregate(x, dell, dell_t, use_kernel=False)

    t_van = _time(vanilla, x, iters=iters)
    t_sort = _time(sorted_scatter, x, iters=iters)
    t_clu = _time(clustered, x, iters=iters)
    t_buck = _time(bucketed, x, iters=iters)

    maxpad_slots = n * max(max_deg, 1)
    rows = [
        {"name": f"aggregation_fig8/rmat{scale}/vanilla",
         "us_per_call": round(t_van, 1),
         "derived": f"edges={g.num_edges}"},
        {"name": f"aggregation_fig8/rmat{scale}/sorted",
         "us_per_call": round(t_sort, 1),
         "derived": f"speedup_vs_vanilla={t_van / t_sort:.2f}x"},
        {"name": f"aggregation_fig8/rmat{scale}/clustered_segment",
         "us_per_call": round(t_clu, 1),
         "derived": f"speedup_vs_vanilla={t_van / t_clu:.2f}x"},
    ]

    # ell (max-degree padding): time it only while the padded gather fits.
    t_ell = None
    if maxpad_slots <= ELL_TIMING_SLOT_BUDGET:
        eidx, ew, _ = ell_from_csr(csr)
        eidx, ew = jnp.asarray(eidx), jnp.asarray(ew)

        @jax.jit
        def ell_maxpad(x, idx=eidx, w=ew):
            return seg_aggregate_ref(x, idx, w)

        t_ell = _time(ell_maxpad, x, iters=iters)
        rows.append({
            "name": f"aggregation_fig8/rmat{scale}/ell",
            "us_per_call": round(t_ell, 1),
            "derived": f"padded_slots={maxpad_slots}"
                       f"({maxpad_slots / csr.nnz:.1f}x_nnz)"})
    else:
        rows.append({
            "name": f"aggregation_fig8/rmat{scale}/ell",
            "us_per_call": 0.0,
            "derived": f"skipped:padded_slots={maxpad_slots}"
                       f"({maxpad_slots / csr.nnz:.1f}x_nnz)"})

    rows.append({
        "name": f"aggregation_fig8/rmat{scale}/bucketed",
        "us_per_call": round(t_buck, 1),
        "derived": f"speedup_vs_vanilla={t_van / t_buck:.2f}x,"
                   f"padded_slots={bucketed_slots}"
                   f"({bucketed_slots / csr.nnz:.2f}x_nnz)"})

    t_kernel = None
    if with_kernel:
        @jax.jit
        def kernel(x, dell=dell, dell_t=dell_t):
            return bucketed_aggregate(x, dell, dell_t, use_kernel=True)

        t_kernel = _time(kernel, x, iters=1)
        # use_kernel=True still falls back to the XLA ref on buckets whose
        # shapes miss the (8, 128) tile — label what actually ran.
        realized = ("pallas_interpret(functional_check)"
                    if feat_dim % 128 == 0 else "xla_ref(unaligned_feat)")
        rows.append({
            "name": f"aggregation_fig8/rmat{scale}/kernel",
            "us_per_call": round(t_kernel, 1),
            "derived": realized})

    layout = {
        "nodes": n,
        "edges": int(csr.nnz),
        "max_degree": max_deg,
        "maxpad_slots": int(maxpad_slots),
        "layout_slots": int(layout_slots),
        "layout_padding_ratio": round(layout_slots / csr.nnz, 4),
        "bucketed_slots": int(bucketed_slots),
        "bucketed_padding_ratio": round(bucketed_slots / csr.nnz, 4),
        "buckets": [[int(b.idx.shape[1]), int(b.idx.shape[0])]
                    for b in dell.buckets],
        "us": {"vanilla": t_van, "sorted": t_sort, "clustered": t_clu,
               "ell": t_ell, "bucketed": t_buck, "kernel": t_kernel},
    }
    # Acceptance bound: the growth-2 ladder guarantees < 2 x nnz padding
    # pre row-alignment (the device slots add a bounded 8-row sliver per
    # bucket, reported above but not asserted — it depends on bucket count,
    # not the ladder).
    if layout_slots > 2 * csr.nnz:
        raise AssertionError(
            f"rmat{scale}: bucketed layout slots {layout_slots} > "
            f"2 x nnz ({2 * csr.nnz})")
    return rows, layout


def run(feat_dim: int = 128, scales=(10, 12, 14), quick: bool = False):
    rows, _ = run_with_artifact(feat_dim, scales, quick)
    return rows


def run_with_artifact(feat_dim: int = 128, scales=(10, 12, 14),
                      quick: bool = False):
    if quick:
        scales = tuple(scales[:2])
    iters = 2 if quick else 5
    rows, layouts = [], {}
    for i, scale in enumerate(scales):
        # Interpret-mode Pallas is far too slow beyond the smallest scale.
        r, layout = _bench_scale(scale, feat_dim, iters, with_kernel=(i == 0))
        rows.extend(r)
        layouts[f"rmat{scale}"] = layout
    xla_keys = ("vanilla", "sorted", "clustered", "ell")
    artifact = {
        "benchmark": "aggregation_fig8",
        "feat_dim": feat_dim,
        "scales": list(scales),
        "quick": quick,
        "rows": rows,
        "layouts": layouts,
        "acceptance": {
            "bucketed_slots_le_2x_nnz": all(
                l["layout_padding_ratio"] <= 2.0 for l in layouts.values()),
            "bucketed_fastest_cpu": all(
                all(l["us"][k] is None or l["us"]["bucketed"] <= l["us"][k]
                    for k in xla_keys)
                for l in layouts.values()),
        },
    }
    return rows, artifact


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--quick", action="store_true",
                    help="reduced scales/iters (the CI bench job)")
    ap.add_argument("--feat-dim", type=int, default=128)
    ap.add_argument("--out", type=str, default=None,
                    help="write the JSON artifact here")
    args = ap.parse_args()
    rows, artifact = run_with_artifact(args.feat_dim, quick=args.quick)
    print("name,us_per_call,derived")
    for row in rows:
        print(f"{row['name']},{row['us_per_call']},{row['derived']}")
    if args.out:
        with open(args.out, "w") as f:
            json.dump(artifact, f, indent=1)
        print(f"# wrote {args.out}")


if __name__ == "__main__":
    main()
