"""Quickstart: train a 3-layer GraphSAGE full-batch on a synthetic SBM graph
(single device), the paper's model configuration at laptop scale.

  PYTHONPATH=src python examples/quickstart.py
"""

from repro.core import GCNConfig, train_gcn_single
from repro.graph import sbm_graph
from repro.graph.generators import sbm_features


def main():
    g = sbm_graph(num_nodes=3000, num_blocks=10, avg_degree=15,
                  homophily=0.85, seed=0)
    x, _ = sbm_features(g, feat_dim=64, noise=2.0, seed=1)
    print(f"graph: {g.num_nodes} nodes / {g.num_edges} edges / 10 classes")

    cfg = GCNConfig(model="sage", in_dim=64, hidden_dim=256, num_classes=10,
                    num_layers=3, dropout=0.5, norm="layer", label_prop=True)
    params, history = train_gcn_single(g, x, cfg, epochs=60, lr=0.01,
                                       log_every=10)
    for h in history:
        print(f"epoch {h['epoch']:3d}  loss {h['loss']:.4f}  "
              f"eval acc {h['eval_acc']:.4f}")
    assert history[-1]["eval_acc"] > 0.9
    print("quickstart OK")


if __name__ == "__main__":
    main()
