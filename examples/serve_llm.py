"""Serve a small LM with batched requests: prefill then batched decode,
using the same serve_step the production decode shapes lower in the
dry-run.

  PYTHONPATH=src python examples/serve_llm.py [--arch tinyllama-1.1b]
"""

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_smoke_arch
from repro.models import init_cache, init_params, serve_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--gen", type=int, default=24)
    args = ap.parse_args()

    cfg = get_smoke_arch(args.arch)
    print(f"arch {cfg.name}: {cfg.num_layers}L d={cfg.d_model} "
          f"(reduced config; production shapes run in the dry-run)")
    key = jax.random.PRNGKey(0)
    params = init_params(key, cfg)
    cache = init_cache(cfg, args.batch, args.prompt_len + args.gen)
    step = jax.jit(lambda p, c, t: serve_step(p, c, t, cfg))

    # prefill the batch of prompts token-by-token (filling the KV cache)
    prompts = jax.random.randint(key, (args.batch, args.prompt_len), 0,
                                 cfg.vocab_size)
    t0 = time.time()
    logits = None
    for i in range(args.prompt_len):
        logits, cache = step(params, cache, prompts[:, i:i + 1])
    print(f"prefill {args.prompt_len} tok x {args.batch} reqs: "
          f"{time.time() - t0:.2f}s")

    # batched greedy decode
    tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    generated = [tok]
    t0 = time.time()
    for _ in range(args.gen - 1):
        logits, cache = step(params, cache, tok)
        tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        generated.append(tok)
    dt = time.time() - t0
    out = jnp.concatenate(generated, axis=1)
    print(f"decoded {args.gen} tok x {args.batch} reqs in {dt:.2f}s "
          f"({dt / max(args.gen - 1, 1) * 1e3:.0f} ms/step)")
    for b in range(args.batch):
        print(f"req {b}: {out[b, :12].tolist()} ...")


if __name__ == "__main__":
    main()
