"""Communication-optimization walkthrough (paper §5-§6, Table 5 story):

1. partition an R-MAT graph, build the remote bipartite graphs,
2. solve MVC per partition pair -> hybrid pre/post classification,
3. compare wire volumes: vanilla / pre / post / hybrid / hybrid+Int2,
4. show the Int2 quantize->wire->dequantize round trip error and the Eqn-8
   speedup regime curve.

  PYTHONPATH=src python examples/quantized_comm_demo.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.perf_model import FUGAKU_A64FX, delta_ratio, speedup_model
from repro.graph import build_partitioned_graph, rmat_graph
from repro.quant import dequantize_packed, quantize_packed, wire_bytes


def main():
    feat = 256
    g = rmat_graph(12, edge_factor=8, seed=0)
    pg = build_partitioned_graph(g, 8, strategy="hybrid", seed=0)
    s = pg.stats
    print(f"R-MAT graph: {g.num_nodes} nodes / {g.num_edges} edges, 8 parts")
    print("\n-- communication volume per GCN layer (feature rows) --")
    fp32 = {k: getattr(s, k) * feat * 4 for k in ("vanilla", "pre", "post", "hybrid")}
    for k, v in fp32.items():
        print(f"  {k:8s} {getattr(s, k):7d} rows  {v / 1e6:8.2f} MB fp32")
    int2 = wire_bytes(s.hybrid, feat, 2)
    print(f"  hybrid+Int2 {'':13s}{int2 / 1e6:8.2f} MB "
          f"({fp32['hybrid'] / int2:.1f}x less than hybrid fp32)")
    print(f"  hybrid vs best single strategy: "
          f"{min(s.pre, s.post) / s.hybrid:.2f}x (paper Table 5: ~1.52x)")

    print("\n-- Int2 round trip (LayerNorm'd features) --")
    x = jax.random.normal(jax.random.PRNGKey(0), (1024, feat))
    x = (x - x.mean(-1, keepdims=True)) / x.std(-1, keepdims=True)
    packed, params = quantize_packed(x, 2, jax.random.PRNGKey(1))
    xd = dequantize_packed(packed, params, 2, feat)
    err = float(jnp.abs(xd - x).mean())
    print(f"  mean abs error {err:.4f} on unit-scale features "
          f"(step {float(params.scale.mean()):.4f})")

    print("\n-- Eqn-8 speedup regimes (Int2, gamma=16) --")
    for vol in (100_000, 10_000, 1_000, 100, 10):
        d = delta_ratio(vol, feat, 2, FUGAKU_A64FX)
        sp = speedup_model(alpha=512, beta=FUGAKU_A64FX.beta, gamma=16, delta=d)
        regime = "throughput-bound" if d < 1 else "latency-bound"
        print(f"  pair volume {vol:7d} rows: delta={d:8.3f} "
              f"speedup={sp:5.2f}x ({regime})")


if __name__ == "__main__":
    main()
