"""End-to-end driver: the paper's full system (Fig 2) at laptop scale.

Pipeline: synthetic graph -> min-cut partition -> MVC hybrid pre/post
aggregation plans -> distributed full-batch GraphSAGE training with Int2
quantized halo communication + masked label propagation, for a few hundred
epochs, with FP32 and DistGNN-style cd-5 comparisons.

  PYTHONPATH=src python examples/train_gcn_distributed.py [--epochs 200]
"""

import argparse
import time

import jax
import numpy as np

from repro.core import (DistConfig, DistributedTrainer, GCNConfig,
                        prepare_distributed)
from repro.core.trainer import _local_aggregate
from repro.graph import build_partitioned_graph, partition_stats, sbm_graph
from repro.graph.generators import sbm_features


def time_aggregation(wd, num_layers: int, iters: int = 20) -> dict:
    """Measured per-epoch *local aggregation* time per backend (us).

    One training epoch runs ``num_layers`` forward aggregations plus their
    transposes in the backward pass — report 2 x num_layers x per-call.
    """
    out = {}
    for backend in ("coo", "ell"):
        f = jax.jit(jax.vmap(lambda h, w: _local_aggregate(h, w, backend)))
        jax.block_until_ready(f(wd.x, wd))
        t0 = time.perf_counter()
        for _ in range(iters):
            out_ = f(wd.x, wd)
        jax.block_until_ready(out_)
        per_call = (time.perf_counter() - t0) / iters * 1e6
        out[backend] = per_call * 2 * num_layers
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--epochs", type=int, default=200)
    ap.add_argument("--nparts", type=int, default=8)
    ap.add_argument("--nodes", type=int, default=4000)
    ap.add_argument("--agg-backend", default="ell", choices=("coo", "ell"),
                    help="aggregation realization: degree-bucketed "
                         "blocked-ELL kernel dispatch (default) or the COO "
                         "scatter-add parity fallback")
    args = ap.parse_args()

    g = sbm_graph(args.nodes, 10, avg_degree=14, homophily=0.8, seed=0)
    x, _ = sbm_features(g, 64, noise=2.5, seed=1)
    gn = g.mean_normalized()
    print(f"graph: {g.num_nodes} nodes / {g.num_edges} edges")

    # 1-2: partition + split into local / pre-aggr / post-aggr graphs (MVC)
    pg = build_partitioned_graph(gn, args.nparts, strategy="hybrid", seed=0)
    st = pg.stats
    print(f"partition: {partition_stats(g, pg.part)}")
    print(f"halo volume rows/layer: vanilla={st.vanilla} pre={st.pre} "
          f"post={st.post} hybrid={st.hybrid} "
          f"(hybrid saves {min(st.pre, st.post) / max(st.hybrid, 1):.2f}x)")
    wd = prepare_distributed(gn, x, pg)

    agg_us = time_aggregation(wd, num_layers=3)
    print(f"local aggregation / epoch: coo={agg_us['coo']:.0f}us "
          f"ell={agg_us['ell']:.0f}us "
          f"(bucketed-ELL speedup {agg_us['coo'] / agg_us['ell']:.2f}x; "
          f"training with --agg-backend {args.agg_backend})")

    ab = args.agg_backend
    runs = [
        ("FP32 sync", DistConfig(nparts=args.nparts, bits=0, lr=0.01,
                                 agg_backend=ab)),
        ("Int2 + LP (SuperGCN)", DistConfig(nparts=args.nparts, bits=2,
                                            lr=0.01, agg_backend=ab)),
        ("FP32 cd-5 (DistGNN-like)", DistConfig(nparts=args.nparts, bits=0,
                                                cd=5, lr=0.01,
                                                agg_backend=ab)),
    ]
    for name, dc in runs:
        cfg = GCNConfig(model="sage", in_dim=64, hidden_dim=256,
                        num_classes=10, num_layers=3, dropout=0.5,
                        norm="layer", label_prop=True)
        tr = DistributedTrainer(cfg, dc, wd, mode="vmap", seed=0)
        t0 = time.time()
        tr.fit(args.epochs)
        acc = tr.evaluate()
        print(f"{name:28s} {args.epochs} epochs in {time.time() - t0:6.1f}s "
              f"-> eval acc {acc:.4f}")


if __name__ == "__main__":
    main()
