"""End-to-end driver: the paper's full system (Fig 2) at laptop scale.

Pipeline: synthetic graph -> min-cut partition -> MVC hybrid pre/post
aggregation plans -> distributed full-batch GraphSAGE training with Int2
quantized halo communication + masked label propagation, for a few hundred
epochs, with FP32 and DistGNN-style cd-5 comparisons.

Each comparison run is one declarative :class:`repro.run.RunSpec` handed
to ``build_session`` (a shared BuildCache reuses the graph + partition
across them); print ``spec.to_json()`` for any row to reproduce it with
``python -m repro.launch.train --gcn --spec file.json``.

  PYTHONPATH=src python examples/train_gcn_distributed.py [--epochs 200]
"""

import argparse
import time

import jax

from repro.core.trainer import _local_aggregate
from repro.graph import partition_stats
from repro.run import BuildCache, RunSpec, build_session


def time_aggregation(wd, num_layers: int, iters: int = 20) -> dict:
    """Measured per-epoch *local aggregation* time per backend (us).

    One training epoch runs ``num_layers`` forward aggregations plus their
    transposes in the backward pass — report 2 x num_layers x per-call.
    """
    out = {}
    for backend in ("coo", "ell"):
        f = jax.jit(jax.vmap(lambda h, w: _local_aggregate(h, w, backend)))
        jax.block_until_ready(f(wd.x, wd))
        t0 = time.perf_counter()
        for _ in range(iters):
            out_ = f(wd.x, wd)
        jax.block_until_ready(out_)
        per_call = (time.perf_counter() - t0) / iters * 1e6
        out[backend] = per_call * 2 * num_layers
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--epochs", type=int, default=200)
    ap.add_argument("--nparts", type=int, default=8)
    ap.add_argument("--nodes", type=int, default=4000)
    ap.add_argument("--agg-backend", default="ell", choices=("coo", "ell"),
                    help="aggregation realization: degree-bucketed "
                         "blocked-ELL kernel dispatch (default) or the COO "
                         "scatter-add parity fallback")
    args = ap.parse_args()

    base = RunSpec().with_overrides([
        f"graph.nodes={args.nodes}", "graph.classes=10",
        "graph.avg_degree=14", "graph.homophily=0.8", "graph.seed=0",
        "graph.feat_dim=64", "graph.feat_noise=2.5",
        f"partition.nparts={args.nparts}",
        f"schedule.agg_backend={args.agg_backend}",
        "model.hidden_dim=256", f"exec.epochs={args.epochs}", "exec.lr=0.01",
    ])
    cache = BuildCache()
    g, _ = cache.graph(base)
    pg = cache.partition(base, g)
    st = pg.stats
    print(f"graph: {g.num_nodes} nodes / {g.num_edges} edges")
    print(f"partition: {partition_stats(g, pg.part)}")
    print(f"halo volume rows/layer: vanilla={st.vanilla} pre={st.pre} "
          f"post={st.post} hybrid={st.hybrid} "
          f"(hybrid saves {min(st.pre, st.post) / max(st.hybrid, 1):.2f}x)")

    runs = [
        ("FP32 sync", []),
        ("Int2 + LP (SuperGCN)", ["schedule.bits=2"]),
        ("FP32 cd-5 (DistGNN-like)", ["schedule.cd=5"]),
    ]
    first = True
    for name, overrides in runs:
        spec = base.with_overrides(overrides)
        session = build_session(spec, cache=cache)
        if first:
            agg_us = time_aggregation(session.wd, num_layers=3)
            print(f"local aggregation / epoch: coo={agg_us['coo']:.0f}us "
                  f"ell={agg_us['ell']:.0f}us "
                  f"(bucketed-ELL speedup {agg_us['coo'] / agg_us['ell']:.2f}x; "
                  f"training with --agg-backend {args.agg_backend})")
            first = False
        t0 = time.time()
        session.fit(log_every=0)
        acc = session.evaluate()
        print(f"{name:28s} {args.epochs} epochs in {time.time() - t0:6.1f}s "
              f"-> eval acc {acc:.4f}  [{spec.content_hash()}]")


if __name__ == "__main__":
    main()
